"""Cost-model and autotuner invariants (pure model — no multi-device mesh).

The live-mesh behaviour (auto-tuned multiplexer shuffling correctly on 8
fake devices, empirical refinement) runs in tests/test_exchange_equiv.py via
the subprocess driver.
"""

import dataclasses
import types

import jax
import numpy as np
import pytest

from repro.core import schedule as S
from repro.core import topology as T
from repro.core.autotune import (
    TableStats,
    candidate_configs,
    exchange_makespan,
    pod_strategy_times,
    tune_multiplexer,
)
from repro.core.multiplexer import make_multiplexer

# Zero launch latencies isolate the wire/HBM terms of the model.
ZERO_LAT = dataclasses.replace(
    T.V5E, ici_launch_latency=0.0, kernel_launch_latency=0.0
)


def _mesh8():
    """Mesh stand-in: the tuner only reads axis_names and devices.shape."""
    return types.SimpleNamespace(axis_names=("q",), devices=np.empty((8,)))


# ----------------------------------------------------------------------------
# The per-phase cost functions.
# ----------------------------------------------------------------------------

@pytest.mark.parametrize("n", [2, 4, 8, 16])
@pytest.mark.parametrize("msg", [1e3, 1e6])
def test_shuffle_time_agrees_with_schedule_link_time(n, msg):
    """On a non-blocking switch at zero launch latency, the per-phase model
    sums to exactly the analytic schedule_link_time — scheduled and not."""
    got = T.shuffle_time(n, msg, ZERO_LAT, "round_robin", 1, "switch")
    want = S.schedule_link_time(
        n, msg, ZERO_LAT.ici_link_bandwidth, scheduled=True
    )
    assert got == pytest.approx(want)

    got_x = T.shuffle_time(n, msg, ZERO_LAT, "xla", 1, "switch")
    want_x = S.schedule_link_time(
        n, msg, ZERO_LAT.ici_link_bandwidth, scheduled=False
    )
    assert got_x == pytest.approx(want_x)


def test_exchange_makespan_agrees_at_chunks1():
    """At chunks=1 the makespan is exactly pack + data phases + counts phases
    (no overlap term), i.e. schedule_link_time plus the launch budget."""
    stats = TableStats(rows=1024, row_bytes=16)
    n = 8
    got = exchange_makespan(
        stats, n, "round_robin", "xla", 1, 1, ZERO_LAT, "switch"
    )
    bw = ZERO_LAT.ici_link_bandwidth
    want = (
        T.pack_time(1024, 16, n, ZERO_LAT, "xla")
        + S.schedule_link_time(n, 1024 * 16, bw, scheduled=True)
        + S.schedule_link_time(n, 4, bw, scheduled=True)
    )
    assert got == pytest.approx(want)


def test_modeled_times_monotone_in_message_size():
    sizes = [1e2, 1e3, 1e4, 1e5, 1e6]
    for topology in ("switch", "ring"):
        for impl in ("round_robin", "one_factorization", "xla"):
            times = [
                T.shuffle_time(8, m, T.V5E, impl, 1, topology) for m in sizes
            ]
            assert times == sorted(times) and times[0] < times[-1], (
                impl, topology, times,
            )
    phases = [T.phase_time(m, T.V5E) for m in sizes]
    assert phases == sorted(phases) and phases[0] < phases[-1]
    for impl in ("xla", "pallas"):
        packs = [T.pack_time(int(m), 16, 8, T.V5E, impl) for m in sizes]
        assert packs == sorted(packs) and packs[0] < packs[-1]


def test_makespan_monotone_in_rows():
    rows = [256, 1024, 4096, 16384]
    for pack_impl in ("xla", "pallas"):
        ms = [
            exchange_makespan(TableStats(r, 16), 8, pack_impl=pack_impl)
            for r in rows
        ]
        assert ms == sorted(ms) and ms[0] < ms[-1]


def test_ring_phase_loads():
    # shift by +-1 is conflict-free; shift by k loads the ring min(k, n-k)-fold
    sched = S.make_schedule(8, "shift")
    assert S.schedule_ring_loads(sched) == [1, 2, 3, 4, 3, 2, 1]
    assert [S.ring_hops(8, k) for k in range(1, 8)] == [1, 2, 3, 4, 3, 2, 1]
    # every phase of any verified schedule moves every unit -> load >= 1
    for kind in ("shift", "one_factorization"):
        for load in S.schedule_ring_loads(S.make_schedule(8, kind)):
            assert load >= 1


# ----------------------------------------------------------------------------
# The tuner.
# ----------------------------------------------------------------------------

def test_tune_tiny_messages_run_unchunked():
    cfg = tune_multiplexer(_mesh8(), TableStats(rows=64, row_bytes=8))
    assert cfg.pipeline_chunks == 1
    assert cfg.transport_chunks == 1
    assert cfg.modeled_s > 0


def test_tune_large_messages_pipeline_chunked():
    cfg = tune_multiplexer(_mesh8(), TableStats(rows=1 << 20, row_bytes=64))
    assert cfg.pipeline_chunks > 1
    assert cfg.impl in ("round_robin", "one_factorization")  # scheduled wins


def test_tune_is_argmin_of_its_own_candidates():
    cfg = tune_multiplexer(_mesh8(), TableStats(rows=1 << 16, row_bytes=16))
    modeled = [c[-1] for c in cfg.candidates]
    assert cfg.modeled_s == pytest.approx(min(modeled))
    impl, pack, C, t, best = cfg.candidates[0]
    assert (impl, pack, C, t) == (
        cfg.impl, cfg.pack_impl, cfg.pipeline_chunks, cfg.transport_chunks
    )


def test_tune_respects_divisibility():
    # 21 rows: no candidate chunking divides it -> unchunked
    cfg = tune_multiplexer(_mesh8(), TableStats(rows=21, row_bytes=1 << 20))
    assert cfg.pipeline_chunks == 1 and cfg.transport_chunks == 1
    # one multiplexer serving exchanges of 4 and 6 rows: gcd=2 caps chunking
    for _, _, C, t in candidate_configs(
        8, [TableStats(4, 8), TableStats(6, 8)]
    ):
        assert C * t in (1, 2)


def test_tune_trivial_on_single_unit_axis():
    mesh1 = types.SimpleNamespace(axis_names=("q",), devices=np.empty((1,)))
    cfg = tune_multiplexer(mesh1, TableStats(rows=4096, row_bytes=16))
    assert cfg.pipeline_chunks == 1 and cfg.modeled_s == 0.0


def _pod_mesh_stub(pods=2, n=4):
    """Two-level mesh stand-in (the tuner reads axis_names + shape only)."""
    return types.SimpleNamespace(
        axis_names=("pod", "q"), devices=np.empty((pods, n))
    )


# ----------------------------------------------------------------------------
# The DCI (network in the large) extension.
# ----------------------------------------------------------------------------

def test_phase_time_network_selects_dci_constants():
    chip = dataclasses.replace(
        T.V5E, ici_link_bandwidth=100e9, dci_bandwidth=10e9,
        ici_launch_latency=1e-6, dci_launch_latency=7e-6,
    )
    msg = 1e6
    ici = T.phase_time(msg, chip, network="ici")
    dci = T.phase_time(msg, chip, network="dci")
    assert ici == pytest.approx(1e-6 + msg / 100e9)
    assert dci == pytest.approx(7e-6 + msg / 10e9)
    with pytest.raises(ValueError, match="network level"):
        T.phase_time(msg, chip, network="numa")


def test_shuffle_time_dci_scales_with_dci_bandwidth():
    fast = dataclasses.replace(ZERO_LAT, dci_launch_latency=0.0)
    slow = dataclasses.replace(fast, dci_bandwidth=fast.dci_bandwidth / 4)
    a = T.shuffle_time(4, 1e6, fast, "round_robin", topology="switch",
                       network="dci")
    b = T.shuffle_time(4, 1e6, slow, "round_robin", topology="switch",
                       network="dci")
    assert b == pytest.approx(4 * a)


def test_makespan_charges_the_pod_hop():
    """Two-level pricing = coarse DCI hop + the P-fold in-pod shuffle:
    strictly above single-pod, and monotone in the pod count."""
    stats = TableStats(rows=4096, row_bytes=16)
    ms = [exchange_makespan(stats, 8, num_pods=p) for p in (1, 2, 4, 8)]
    assert ms == sorted(ms) and ms[0] < ms[1]


def test_pod_strategy_threshold_flips_with_build_size():
    """Tiny build sides broadcast (the paper's n-1 threshold); large ones
    reshard — each byte crosses DCI once instead of once per pod."""
    n, pods = 4, 2
    tiny = pod_strategy_times(TableStats(rows=64, row_bytes=8), n, pods)
    huge = pod_strategy_times(TableStats(rows=1 << 22, row_bytes=64), n, pods)
    assert set(tiny) == {"broadcast", "reshard"}
    assert tiny["broadcast"] < tiny["reshard"]
    assert huge["reshard"] < huge["broadcast"]


def test_tune_cross_pod_strategy():
    mesh = _pod_mesh_stub()
    probe = TableStats(rows=4096, row_bytes=16)
    cfg = tune_multiplexer(
        mesh, probe, broadcast_stats=TableStats(rows=64, row_bytes=8)
    )
    assert cfg.cross_pod == "broadcast"
    assert cfg.cross_pod_modeled_s is not None
    cfg_big = tune_multiplexer(
        mesh, probe, broadcast_stats=TableStats(rows=1 << 22, row_bytes=64)
    )
    assert cfg_big.cross_pod == "reshard"
    # single-pod meshes never pick a cross-pod strategy
    flat = tune_multiplexer(
        _mesh8(), probe, broadcast_stats=TableStats(rows=64, row_bytes=8)
    )
    assert flat.cross_pod is None


def test_tune_on_pod_mesh_returns_legal_knobs():
    cfg = tune_multiplexer(_pod_mesh_stub(), TableStats(rows=1 << 16,
                                                        row_bytes=16))
    assert cfg.impl in ("xla", "round_robin", "one_factorization")
    assert (1 << 16) % cfg.pipeline_chunks == 0
    # candidates are priced with the pod hop: every modeled time exceeds the
    # bare single-pod model of the same knob setting
    for impl, pack, C, t, modeled in cfg.candidates:
        single = exchange_makespan(
            TableStats(rows=1 << 16, row_bytes=16), 4, impl, pack, C, t
        )
        assert modeled > single


def test_make_multiplexer_auto_applies_tuned_knobs():
    mesh = jax.sharding.Mesh(np.asarray(jax.devices()[:1]), ("q",))
    mux = make_multiplexer(
        mesh, auto=True, table_stats=TableStats(rows=256, row_bytes=8)
    )
    assert mux.pipeline_chunks == 1  # single-unit axis: trivial config
    with pytest.raises(ValueError, match="table_stats"):
        make_multiplexer(mesh, auto=True)
