"""Query-serving engine: concurrency stress, warm-path regression, restart.

Three contracts from the PR spec:

* **no slot leak + starvation bound** — randomized multi-tenant arrival
  mixes drain with ``free + live == capacity``, and under fair-share no
  request (hence no tenant) queues more than ``ceil(N / slots) + tenants``
  scheduling rounds, even when one tenant floods the queue;
* **bit-identity** — every admitted query's result equals its solo
  ``run_query``/``execute_plan`` run exactly (the engine's shared
  multiplexer and cached executors change latency, never bytes);
* **zero replans on the warm path** — all nine TPC-H templates served
  twice: the second pass makes ZERO ``plan_physical`` calls (counter
  hook) and returns results bit-identical to the cold pass; a separate
  process reloads persisted plans from disk without planning at all.
"""

import math
import os
import subprocess
import sys

import jax
import numpy as np
import pytest

from repro.relational import datagen
from repro.relational.context import ExecutionContext
from repro.relational.planner import tpch
from repro.relational.planner.physical import plan_physical
from repro.relational.planner.plan_cache import PlanCache, plan_key
from repro.serve import QueryRequest, QueryServeEngine, make_query_mix

CTX1 = ExecutionContext(num_shards=1)

SF = 0.004


@pytest.fixture(scope="module")
def tabs():
    return datagen.gen_all(SF)


def _tables(tabs, queries):
    names = sorted({t for pq in queries for t in pq.tables})
    return {name: tabs[name] for name in names}


def _trees_equal(a, b) -> bool:
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    return len(la) == len(lb) and all(
        np.array_equal(np.asarray(x), np.asarray(y)) for x, y in zip(la, lb)
    )


# ---------------------------------------------------------------------------
# Concurrency stress: randomized multi-tenant arrival mixes.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", [0, 1, 2])
def test_randomized_mix_no_leak_identical_results_no_starvation(tabs, seed):
    templates = [tpch.ALL_QUERIES[n]() for n in ("q1", "q6", "q14")]
    tables = _tables(tabs, templates)
    tenants = ("alice", "bob", "carol")
    n_req, slots = 10, 2
    reqs = make_query_mix(templates, tenants, n_req, seed=seed,
                          max_arrival_round=3)
    engine = QueryServeEngine(tables, CTX1, num_slots=slots,
                              cache=PlanCache())
    done = engine.serve(reqs)

    # no slot leak after drain
    engine.alloc.check()
    assert engine.alloc.num_free == slots and not engine.alloc.live
    assert len(done) == n_req

    # bit-identical to the solo run of the same template
    solo = {pq.name: tpch.run_query(pq, tables, CTX1) for pq in templates}
    for r in done:
        assert _trees_equal(r.result, solo[r.query.name]), r.query.name

    # starvation bound: every round admits up to ``slots`` requests and
    # fair-share rotates tenants, so nobody queues past this bound
    bound = math.ceil(n_req / slots) + len(tenants)
    assert max(r.queue_rounds for r in done) <= bound


def test_flooding_tenant_cannot_starve_light_tenant(tabs):
    q6 = tpch.ALL_QUERIES["q6"]()
    tables = _tables(tabs, [q6])
    flood = [QueryRequest("heavy", q6) for _ in range(8)]
    light = [QueryRequest("light", q6) for _ in range(2)]
    engine = QueryServeEngine(tables, CTX1, num_slots=1,
                              cache=PlanCache())
    done = engine.serve(flood + light)
    engine.alloc.check()
    # fair-share: with one slot the two tenants alternate, so the light
    # tenant's requests clear within the first few rounds instead of
    # waiting behind the flood
    waits = [r.queue_rounds for r in done if r.tenant == "light"]
    assert max(waits) <= 3, waits
    served_order = [r.tenant for r in done[:4]]
    assert "light" in served_order, served_order


def test_admission_respects_arrival_rounds(tabs):
    q1 = tpch.ALL_QUERIES["q1"]()
    tables = _tables(tabs, [q1])
    early = QueryRequest("a", q1, arrival_round=0)
    late = QueryRequest("a", q1, arrival_round=5)
    engine = QueryServeEngine(tables, CTX1, num_slots=2,
                              cache=PlanCache())
    engine.serve([late, early])
    assert early.admitted_round == 0
    assert late.admitted_round >= 5
    assert late.queue_rounds == 0  # waiting for arrival is not queueing


# ---------------------------------------------------------------------------
# Warm-path regression: all nine queries, zero replans, bit-identical.
# ---------------------------------------------------------------------------

def test_warm_path_all_nine_queries_zero_replans(tabs):
    templates = [make() for make in tpch.ALL_QUERIES.values()]
    tables = _tables(tabs, templates)
    engine = QueryServeEngine(tables, CTX1, num_slots=3,
                              cache=PlanCache())
    cold = engine.serve([QueryRequest("t", pq) for pq in templates])
    assert all(not r.plan_cache_hit for r in cold)

    before = plan_physical.calls
    warm = engine.serve([QueryRequest("t", pq) for pq in templates])
    assert plan_physical.calls == before, "warm path replanned"
    assert all(r.plan_cache_hit and r.executor_cache_hit for r in warm)

    by_name_cold = {r.query.name: r.result for r in cold}
    for r in warm:
        assert _trees_equal(r.result, by_name_cold[r.query.name]), r.query.name
    # and cold == solo execute path for a spot-checked pair
    for name in ("q3", "q17"):
        pq = next(p for p in templates if p.name == name)
        assert _trees_equal(by_name_cold[name], tpch.run_query(pq, tables, CTX1))


_RESTART_SCRIPT = """
import os
from repro.relational import datagen
from repro.relational.planner import tpch
from repro.relational.planner.physical import plan_physical
from repro.relational.planner.plan_cache import PlanCache, plan_key

pq = tpch.ALL_QUERIES["q17"]()
catalog = {{t: int(c) for t, c in zip({tnames!r}, {caps!r})}}
key = plan_key(pq.logical, catalog, 8)
assert key.digest == {digest!r}, "key not stable across processes"
cache = PlanCache(cache_dir={cache_dir!r})
plan = cache.lookup(key)
assert plan is not None, "persisted plan not found"
assert plan_physical.calls == 0, "restart path planned"
print("EXPLAIN_SHA", __import__("hashlib").sha256(
    plan.explain().encode()).hexdigest())
"""


def test_plan_cache_survives_process_restart(tabs, tmp_path):
    """Cross-process half of the cache: the key derives identically in a
    fresh interpreter (no id()/hash-seed leakage) and the persisted plan
    loads without a single ``plan_physical`` call."""
    import hashlib

    pq = tpch.ALL_QUERIES["q17"]()
    catalog = {t: tabs[t].capacity for t in pq.tables}
    key = plan_key(pq.logical, catalog, 8)
    cache = PlanCache(cache_dir=str(tmp_path))
    plan, hit = cache.get_plan(key, lambda: pq.plan(catalog, 8))
    assert not hit

    script = _RESTART_SCRIPT.format(
        tnames=tuple(catalog), caps=tuple(catalog.values()),
        digest=key.digest, cache_dir=str(tmp_path),
    )
    env = dict(os.environ, JAX_PLATFORMS="cpu")
    env["PYTHONPATH"] = (
        os.path.join(os.path.dirname(__file__), "..", "src")
        + os.pathsep + env.get("PYTHONPATH", "")
    )
    proc = subprocess.run(
        [sys.executable, "-c", script],
        capture_output=True, text=True, timeout=180, env=env,
    )
    assert proc.returncode == 0, proc.stdout + "\n" + proc.stderr
    expect = hashlib.sha256(plan.explain().encode()).hexdigest()
    assert f"EXPLAIN_SHA {expect}" in proc.stdout
