"""Per-kernel allclose sweeps against the pure-jnp oracles (interpret mode)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.flash_attention import flash_attention as flash_kernel
from repro.kernels.ssd_scan import ssd_scan as ssd_kernel

KEY = jax.random.PRNGKey(7)


# ---------------------------------------------------------------------------
# flash_attention: shape × dtype × causal sweep.
# ---------------------------------------------------------------------------

FLASH_CASES = [
    # (B, H, KH, Sq, Sk, D, causal, bq, bk)
    (2, 4, 2, 256, 256, 64, True, 128, 128),
    (1, 8, 8, 128, 128, 32, True, 64, 64),
    (2, 4, 1, 128, 256, 64, False, 64, 128),
    (1, 2, 2, 512, 512, 128, True, 128, 128),
    (1, 12, 4, 128, 128, 64, True, 128, 128),
]


@pytest.mark.parametrize("case", FLASH_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_matches_oracle(case, dtype):
    B, H, KH, Sq, Sk, D, causal, bq, bk = case
    q = jax.random.normal(KEY, (B, H, Sq, D), dtype)
    k = jax.random.normal(jax.random.fold_in(KEY, 1), (B, KH, Sk, D), dtype)
    v = jax.random.normal(jax.random.fold_in(KEY, 2), (B, KH, Sk, D), dtype)
    out = flash_kernel(q, k, v, causal=causal, block_q=bq, block_k=bk)
    want = ref.flash_attention_ref(q, k, v, causal=causal)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(want, np.float32), rtol=tol, atol=tol
    )


def test_flash_vjp_grads_match_sdpa():
    B, S, H, KH, D = 2, 256, 4, 2, 64
    q = jax.random.normal(KEY, (B, S, H, D))
    k = jax.random.normal(jax.random.fold_in(KEY, 1), (B, S, KH, D))
    v = jax.random.normal(jax.random.fold_in(KEY, 2), (B, S, KH, D))
    from repro.models.layers import sdpa

    g_ref = jax.grad(lambda q: sdpa(q, k, v, causal=True).sum())(q)
    g_fl = jax.grad(lambda q: ops.flash_attention_vjp(q, k, v, True).sum())(q)
    np.testing.assert_allclose(np.asarray(g_fl), np.asarray(g_ref), rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# ssd_scan: shape sweep + state chaining.
# ---------------------------------------------------------------------------

SSD_CASES = [
    # (B, L, H, P, N, chunk, hb)
    (2, 32, 8, 16, 32, 8, 4),
    (1, 64, 16, 8, 16, 16, 8),
    (2, 16, 4, 32, 64, 16, 4),
    (1, 128, 8, 64, 128, 32, 8),
]


@pytest.mark.parametrize("case", SSD_CASES)
def test_ssd_scan_matches_oracle(case):
    B, L, H, P, N, chunk, hb = case
    x = jax.random.normal(KEY, (B, L, H, P))
    dt = jax.nn.softplus(jax.random.normal(jax.random.fold_in(KEY, 1), (B, L, H)))
    A = -jnp.exp(jax.random.normal(jax.random.fold_in(KEY, 2), (H,)))
    Bm = jax.random.normal(jax.random.fold_in(KEY, 3), (B, L, 1, N))
    Cm = jax.random.normal(jax.random.fold_in(KEY, 4), (B, L, 1, N))
    y, fin = ssd_kernel(x, dt, A, Bm, Cm, chunk=chunk, head_block=hb)
    yr, finr = ref.ssd_scan_ref(x, dt, A, Bm, Cm, chunk=chunk)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(fin), np.asarray(finr), rtol=2e-4, atol=2e-4)


def test_ssd_scan_initial_state_chaining():
    B, L, H, P, N = 2, 32, 4, 16, 32
    x = jax.random.normal(KEY, (B, L, H, P))
    dt = jax.nn.softplus(jax.random.normal(KEY, (B, L, H)))
    A = -jnp.exp(jax.random.normal(KEY, (H,)))
    Bm = jax.random.normal(KEY, (B, L, 1, N))
    Cm = jax.random.normal(jax.random.fold_in(KEY, 5), (B, L, 1, N))
    y_all, s_all = ssd_kernel(x, dt, A, Bm, Cm, chunk=8, head_block=4)
    _, s_half = ssd_kernel(x[:, :16], dt[:, :16], A, Bm[:, :16], Cm[:, :16], chunk=8, head_block=4)
    y2, s2 = ssd_kernel(
        x[:, 16:], dt[:, 16:], A, Bm[:, 16:], Cm[:, 16:], chunk=8, head_block=4,
        initial_state=s_half,
    )
    np.testing.assert_allclose(np.asarray(y2), np.asarray(y_all[:, 16:]), rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(s2), np.asarray(s_all), rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# hash_partition / moe_dispatch.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("T,P,blk", [(512, 8, 128), (1024, 16, 256), (256, 3, 256)])
def test_hash_partition_matches_oracle(T, P, blk):
    keys = jax.random.randint(KEY, (T,), 0, 1 << 30)
    pid, hist = ops.hash_partition(keys, P, block=blk)
    pid_r, hist_r = ref.hash_partition_ref(keys, P, block=min(blk, T))
    np.testing.assert_array_equal(np.asarray(pid), np.asarray(pid_r))
    np.testing.assert_array_equal(np.asarray(hist.sum(0)), np.asarray(hist_r.sum(0)))


@pytest.mark.parametrize("T,E,C", [(512, 16, 8), (2048, 64, 24), (256, 4, 1000)])
def test_moe_dispatch_matches_oracle(T, E, C):
    dest = jax.random.randint(KEY, (T,), 0, E)
    slot, counts = ops.moe_dispatch(dest, E, C)
    slot_r, counts_r = ref.moe_dispatch_ref(dest, E, C)
    np.testing.assert_array_equal(np.asarray(slot), np.asarray(slot_r))
    np.testing.assert_array_equal(np.asarray(counts), np.asarray(counts_r))


@pytest.mark.parametrize("T,B,blk", [(512, 9, 256), (300, 5, 128), (64, 3, 256),
                                     (1000, 17, 256)])
def test_partition_ranks_matches_arrival_order(T, B, blk):
    dest = jax.random.randint(jax.random.fold_in(KEY, T), (T,), 0, B)
    rank, counts = ops.partition_ranks(dest, B, block=blk)
    with ops.use_kernels(False):
        rank_r, counts_r = ops.partition_ranks(dest, B, block=blk)
    np.testing.assert_array_equal(np.asarray(rank), np.asarray(rank_r))
    np.testing.assert_array_equal(np.asarray(counts), np.asarray(counts_r))
    # oracle: arrival-order rank within each destination
    d = np.asarray(dest)
    want = np.zeros(T, np.int64)
    seen: dict = {}
    for t in range(T):
        want[t] = seen.get(d[t], 0)
        seen[d[t]] = want[t] + 1
    np.testing.assert_array_equal(np.asarray(rank), want)
    np.testing.assert_array_equal(np.asarray(counts), np.bincount(d, minlength=B))


@pytest.mark.parametrize("T,P", [(512, 8), (300, 5), (64, 3)])
def test_hash_partition_ranks_fused_matches_ref(T, P):
    keys = jax.random.randint(KEY, (T,), 0, 1 << 30)
    valid = jax.random.bernoulli(jax.random.fold_in(KEY, 9), 0.8, (T,)).astype(jnp.int32)
    dest, rank, counts = ops.hash_partition_ranks(keys, valid, P)
    with ops.use_kernels(False):
        dest_r, rank_r, counts_r = ops.hash_partition_ranks(keys, valid, P)
    np.testing.assert_array_equal(np.asarray(dest), np.asarray(dest_r))
    np.testing.assert_array_equal(np.asarray(rank), np.asarray(rank_r))
    np.testing.assert_array_equal(np.asarray(counts), np.asarray(counts_r))
    # dest matches the hash mod P for valid rows, overflow bin for invalid
    pid = np.asarray(ref.fibonacci_hash_ref(keys) % jnp.uint32(P))
    want = np.where(np.asarray(valid) != 0, pid, P)
    np.testing.assert_array_equal(np.asarray(dest), want)


def test_partition_pack_kernel_matches_ref_oracle():
    from repro.kernels.hash_partition import partition_pack

    dest = jax.random.randint(KEY, (512,), 0, 7)
    hist, local = partition_pack(dest, 7, block=128)
    hist_r, local_r = ref.partition_pack_ref(dest, 7, block=128)
    np.testing.assert_array_equal(np.asarray(hist), np.asarray(hist_r))
    np.testing.assert_array_equal(np.asarray(local), np.asarray(local_r))


def test_use_kernels_toggle():
    keys = jax.random.randint(KEY, (256,), 0, 1 << 30)
    with ops.use_kernels(False):
        assert not ops.kernels_enabled()
        pid, _ = ops.hash_partition(keys, 8)
    with ops.use_kernels(True):
        pid2, _ = ops.hash_partition(keys, 8)
    np.testing.assert_array_equal(np.asarray(pid), np.asarray(pid2))
