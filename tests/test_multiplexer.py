"""CommMultiplexer policy checks that need no optional deps and no mesh.

(The multi-device behaviour — the fallback actually shuffling correctly on a
3-device mesh — runs in tests/test_exchange_equiv.py via the subprocess
driver.)
"""

import warnings

import jax
import numpy as np
import pytest

from repro.core import multiplexer as M
from repro.core import schedule as S
from repro.core.multiplexer import make_multiplexer, resolve_schedule_impl


@pytest.mark.parametrize("sizes,impl,want", [
    ((3,), "one_factorization", "round_robin"),   # odd axis -> shift fallback
    ((4,), "one_factorization", "one_factorization"),
    ((2, 5), "one_factorization", "round_robin"),
    ((1, 3), "one_factorization", "round_robin"),
    ((1,), "one_factorization", "one_factorization"),  # size-1 axes don't shuffle
    ((3,), "round_robin", "round_robin"),
    ((3,), "xla", "xla"),
])
def test_resolve_schedule_impl(sizes, impl, want):
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        assert resolve_schedule_impl(impl, sizes) == want


def test_resolve_schedule_impl_warns_on_fallback():
    M._warned_odd_axis_sizes.clear()
    with pytest.warns(UserWarning, match="one_factorization"):
        resolve_schedule_impl("one_factorization", (3,))


def test_resolve_schedule_impl_warns_once_per_axis_size():
    """The downgrade warning fires once per distinct odd-size set, not on
    every multiplexer build (a long-lived engine builds one per query)."""
    M._warned_odd_axis_sizes.clear()
    with pytest.warns(UserWarning, match="one_factorization"):
        assert resolve_schedule_impl("one_factorization", (3,)) == "round_robin"
    with warnings.catch_warnings():
        warnings.simplefilter("error")  # an identical repeat must stay silent
        assert resolve_schedule_impl("one_factorization", (3,)) == "round_robin"
    with pytest.warns(UserWarning, match="one_factorization"):
        # a different odd size is new information -> warns again
        assert resolve_schedule_impl("one_factorization", (5,)) == "round_robin"
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        assert resolve_schedule_impl("one_factorization", (5,)) == "round_robin"


def test_make_multiplexer_single_device_mesh():
    mesh = jax.sharding.Mesh(np.asarray(jax.devices()[:1]), ("q",))
    for impl in ("xla", "round_robin", "one_factorization"):
        mux = make_multiplexer(mesh, impl=impl)
        assert mux.plan.small_axes == ("q",)


def test_make_multiplexer_carries_pack_knobs():
    mesh = jax.sharding.Mesh(np.asarray(jax.devices()[:1]), ("q",))
    mux = make_multiplexer(
        mesh, impl="round_robin", pack_impl="pallas",
        pipeline_chunks=4, transport_chunks=2,
    )
    assert mux.pack_impl == "pallas"
    assert mux.pipeline_chunks == 4
    assert mux.transport_chunks == 2


# -- non-hypothesis schedule invariants (run even without the test extra) ----

@pytest.mark.parametrize("n", [2, 3, 5, 8, 16])
def test_shift_schedule_verifies(n):
    S.verify_schedule(S.shift_schedule(n))


@pytest.mark.parametrize("n", [2, 4, 8, 16])
def test_one_factorization_verifies_even(n):
    S.verify_schedule(S.one_factorization(n))


@pytest.mark.parametrize("n", [3, 5, 7])
def test_one_factorization_rejects_odd(n):
    with pytest.raises(ValueError):
        S.one_factorization(n)


# -- the EP exchange policy resolver (models/moe._resolve_exchange) ----------

def test_ep_exchange_resolver_mux_wins_both_knobs():
    """ONE source of truth: with an ambient multiplexer BOTH the transport
    and the pack impl come from it, no matter what the config says; without
    one the legacy ``cfg.exchange_impl`` knob drives and the pack falls back
    to the XLA reference.  Flips both knobs to opposite values so a split
    resolver (transport from one source, pack from the other) cannot pass."""
    from repro.configs.base import ModelConfig
    from repro.core.multiplexer import current_multiplexer, use_multiplexer
    from repro.models.moe import _resolve_exchange

    cfg = ModelConfig(
        name="t", family="moe", num_layers=1, d_model=8, num_heads=1,
        num_kv_heads=1, d_ff=16, vocab_size=32, num_experts=4, top_k=1,
        moe_d_ff=16, exchange_impl="one_factorization",
    )
    mesh = jax.sharding.Mesh(np.asarray(jax.devices()[:1]), ("q",))
    mux = make_multiplexer(mesh, impl="xla", pack_impl="pallas")

    # no ambient mux: config transport, reference pack
    assert _resolve_exchange(cfg, current_multiplexer()) == (
        "one_factorization", "xla")
    # ambient mux: both knobs follow its tuned policy
    with use_multiplexer(mux):
        assert _resolve_exchange(cfg, current_multiplexer()) == (
            "xla", "pallas")
    # scope exit restores the config-driven policy
    assert _resolve_exchange(cfg, current_multiplexer()) == (
        "one_factorization", "xla")
