"""Pod-axis scenarios run as a REAL multi-process cluster (2 procs x 4 fake
CPU devices each, by default).

Invoked via the launcher:

    python -m repro.launch.cluster --processes 2 --local-devices 4 \
        tests/_multiproc_driver.py <scenario>

Every process runs the same scenario; collectives over the ``pod`` mesh axis
cross an actual process boundary (Gloo over localhost — the CI stand-in for
DCI).  Each scenario prints "PASS <name>" on success from every process; any
exception fails the run.  ``init_cluster()`` must run before anything
touches jax devices, so keep module-level imports jax-free.
"""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch.cluster import init_cluster  # noqa: E402

INFO = init_cluster()

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
from jax.sharding import PartitionSpec as P  # noqa: E402

from repro.compat import fetch, shard_map  # noqa: E402
from repro.core import exchange  # noqa: E402
from repro.launch.mesh import make_pod_mesh, make_production_mesh  # noqa: E402
from repro.relational.context import ExecutionContext  # noqa: E402


def _pod_mesh():
    mesh = make_pod_mesh()
    assert mesh.axis_names == ("pod", "q"), mesh.axis_names
    return mesh


def scenario_hierarchical_psum():
    """RS-in-pod -> AR-cross-pod -> AG-in-pod equals a flat psum bit-exactly
    across the process boundary (int32 and exactly-representable float32)."""
    mesh = make_pod_mesh(axes=("pod", "data"))
    n = mesh.devices.size
    for dtype, hi in ((jnp.int32, 1 << 20), (jnp.float32, 1 << 12)):
        g = jax.random.randint(
            jax.random.PRNGKey(0), (n * 4, 3), 0, hi
        ).astype(dtype)

        def hier(g):
            return exchange.hierarchical_psum_tree({"g": g}, "data", "pod")["g"]

        def flat(g):
            return exchange.flat_psum_tree({"g": g}, ("pod", "data"))["g"]

        spec = P(("pod", "data"))
        a = jax.jit(shard_map(hier, mesh=mesh, in_specs=spec, out_specs=spec))(g)
        b = jax.jit(shard_map(flat, mesh=mesh, in_specs=spec, out_specs=spec))(g)
        np.testing.assert_array_equal(fetch(a), fetch(b), err_msg=str(dtype))
    print("PASS hierarchical_psum")


def scenario_exchange_over_dci_raises():
    """The hybrid plan rejects any fine-grained shuffle routed over the pod
    axis — at trace time, before a single byte crosses the slow network."""
    from repro.core.multiplexer import make_multiplexer

    mesh = _pod_mesh()
    mux = make_multiplexer(mesh)
    assert mux.plan.large_axes == ("pod",), mux.plan
    x = jnp.zeros((mesh.devices.shape[0], 4), jnp.int32)
    for attempt in (
        lambda: mux.all_to_all(x, "pod"),
        lambda: mux.hash_shuffle(x[:, 0], x, "pod", capacity=2),
        lambda: mux.shuffle_consume(
            x, "pod", lambda acc, c, s: acc, jnp.int32(0)
        ),
    ):
        try:
            attempt()
        except ValueError as e:
            assert "large-network axis" in str(e), e
        else:
            raise AssertionError("exchange over the DCI axis did not raise")
    print("PASS exchange_over_dci_raises")


def scenario_two_level_shuffle():
    """The two-level exchange (coarse cross-process hop + fine in-pod
    shuffle) loses no rows and lands every row on the device owning its
    global hash — across a real process boundary."""
    mesh = _pod_mesh()
    pods, n = mesh.devices.shape
    N = pods * n
    T = 64
    keys = jax.random.randint(jax.random.PRNGKey(3), (N * T,), 0, 10_000,
                              dtype=jnp.int32)
    rows = jnp.stack([keys, keys * 2 + 1], axis=1)

    def shuffle(k, r):
        out_rows, out_valid, dropped = exchange.hash_shuffle_two_level(
            k, r, "q", "pod", capacity=T
        )
        me = jax.lax.axis_index("pod") * n + jax.lax.axis_index("q")
        h = exchange.fibonacci_hash(
            out_rows[:, 0].astype(jnp.uint32)
        ) % jnp.uint32(N)
        ok = jnp.where(out_valid, h == me.astype(jnp.uint32), True).all()
        return out_valid.sum()[None], dropped, ok[None]

    spec = P(("pod", "q"))
    fn = shard_map(shuffle, mesh=mesh, in_specs=(spec, spec),
                   out_specs=(spec, P(), spec), check_vma=False)
    kept, dropped, ok = jax.jit(fn)(keys, rows)
    assert int(fetch(dropped)) == 0
    assert int(fetch(kept).sum()) == N * T
    assert bool(fetch(ok).all())
    print("PASS two_level_shuffle")


def scenario_production_mesh():
    """make_production_mesh derives the pod axis from the live process
    topology instead of the old hardcoded (2, 16, 16)."""
    mesh = make_production_mesh(multi_pod=True)
    assert mesh.axis_names == ("pod", "data", "model")
    assert mesh.devices.shape[0] == jax.process_count(), mesh.devices.shape
    assert mesh.devices.size == jax.device_count()
    print("PASS production_mesh")


def scenario_tpch_pod_mesh():
    """TPC-H Q3 and Q17 on the two-level mesh match the single-host numpy
    oracle — the full vertical slice: pod-aware planner, two-level
    exchanges, cross-pod combine."""
    from repro.relational import datagen, oracle
    from repro.relational.distributed import q3_distributed, q17_distributed

    mesh = _pod_mesh()
    pods, n = mesh.devices.shape
    tabs = datagen.gen_all(0.01)

    got17 = q17_distributed(
        tabs["lineitem"], tabs["part"],
        ExecutionContext(num_shards=pods * n, num_pods=pods),
    )
    np.testing.assert_allclose(
        float(got17), oracle.q17_oracle(tabs["lineitem"], tabs["part"]),
        rtol=1e-3,
    )

    got3 = q3_distributed(
        tabs["customer"], tabs["orders"], tabs["lineitem"],
        ExecutionContext(num_shards=pods * n, num_pods=pods),
    )
    want3 = oracle.q3_oracle(tabs["customer"], tabs["orders"], tabs["lineitem"])
    assert [int(k) for k in got3["o_orderkey"]] == \
        [int(k) for k in want3["o_orderkey"]]
    np.testing.assert_allclose(
        np.asarray(got3["revenue"], np.float64),
        np.asarray(want3["revenue"], np.float64), rtol=1e-3,
    )
    print("PASS tpch_pod_mesh")


def scenario_tuner_dci_aware():
    """tune_multiplexer on the live two-level mesh prices the DCI hop and
    picks a cross-pod strategy for the build side."""
    from repro.core.autotune import TableStats, exchange_makespan, tune_multiplexer

    mesh = _pod_mesh()
    pods, n = mesh.devices.shape
    stats = TableStats(rows=4096, row_bytes=16)
    cfg = tune_multiplexer(
        mesh, stats, broadcast_stats=TableStats(rows=128, row_bytes=12)
    )
    assert cfg.impl in ("xla", "round_robin", "one_factorization")
    assert cfg.cross_pod in ("broadcast", "reshard"), cfg
    # The two-level makespan must charge the coarse DCI hop: strictly more
    # than the same exchange priced single-pod.
    one = exchange_makespan(stats, n)
    two = exchange_makespan(stats, n, num_pods=pods)
    assert two > one, (one, two)
    # A big build side flips the choice to reshard.
    cfg_big = tune_multiplexer(
        mesh, stats, broadcast_stats=TableStats(rows=1 << 20, row_bytes=64)
    )
    assert cfg_big.cross_pod == "reshard", cfg_big
    print("PASS tuner_dci_aware")


def scenario_ep_dispatch_two_level():
    """MoE expert dispatch routed through the two-level fabric across a REAL
    process boundary is token-for-token identical to the flat all-to-all
    oracle (the same tokens shipped over a single joint mesh axis), and the
    flat route on the pod mesh is rejected at trace time — the exchange
    either takes the coarse-then-fine hops or does not run at all."""
    from repro.configs.base import ModelConfig
    from repro.core.multiplexer import make_multiplexer, use_multiplexer
    from repro.distributed.sharding import (
        MeshContext, default_rules, mesh_context,
    )
    from repro.models import moe

    cfg = ModelConfig(
        name="t", family="moe", num_layers=1, d_model=16, num_heads=2,
        num_kv_heads=2, d_ff=32, vocab_size=64, num_experts=8, top_k=2,
        moe_d_ff=32, moe_impl="ep_shardmap", capacity_factor=8.0,
        dtype="float32", param_dtype="float32",
    )
    # identical on every process (same seed) — the cluster-wide replicas
    params = moe.init_moe_layer(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (16, cfg.d_model), jnp.float32)

    pod_mesh = make_pod_mesh(axes=("pod", "model"))
    pods, n = pod_mesh.devices.shape
    N = pods * n
    assert cfg.num_experts % N == 0 and x.shape[0] % N == 0, (cfg, N)

    flat_mesh = jax.sharding.Mesh(np.asarray(jax.devices()), ("model",))
    ctx_flat = MeshContext(mesh=flat_mesh, rules=default_rules(False),
                           data_axes=())
    ctx_pod = MeshContext(mesh=pod_mesh, rules=default_rules(True),
                          pod_axis="pod", data_axes=())

    with mesh_context(ctx_flat):
        want = fetch(moe.moe_ep(params, cfg, x))
    with mesh_context(ctx_pod):
        got = fetch(moe.moe_ep(params, cfg, x))
    np.testing.assert_array_equal(np.asarray(want), np.asarray(got))

    # a single-level multiplexer must not silently flat-route over DCI
    mux_flat = make_multiplexer(flat_mesh)
    try:
        with mesh_context(ctx_pod), use_multiplexer(mux_flat):
            moe.moe_ep(params, cfg, x)
    except ValueError as e:
        assert "single-level multiplexer" in str(e), e
    else:
        raise AssertionError("flat mux on the pod mesh did not raise")
    print("PASS ep_dispatch_two_level")


def scenario_salted_pod_shuffle():
    """Salting works ACROSS the pod axis: Zipf(1.2) ``l_partkey`` Q17 on
    the 2x4 two-level mesh (the heavy key's sub-keys spread over all 8
    global shards, crossing the process boundary), measured max/fair-share
    strictly below the unsalted run, result equal to the numpy oracle."""
    from repro.relational import datagen, oracle
    from repro.relational import stats as rstats
    from repro.relational.planner import executor, tpch

    mesh = _pod_mesh()
    pods, n = mesh.devices.shape
    tabs = datagen.gen_all(0.01, zipf_partkey=1.2)
    pq = tpch.q17(brand=11, container=25)  # selects the heaviest part
    want = oracle.q17_oracle(tabs["lineitem"], tabs["part"], 11, 25)
    assert want > 0
    catalog = {t: tabs[t].capacity for t in pq.tables}
    stats = rstats.collect_stats({t: tabs[t] for t in pq.tables})

    plan = pq.plan(catalog, pods * n, num_pods=pods, stats=stats)
    assert "salted x" in plan.explain()
    run = executor.compile_plan(plan, tabs)
    raw, qt = run.collect(run.dispatch())
    got = pq.finalize(raw)
    np.testing.assert_allclose(float(got), want, rtol=1e-3)
    (edge,) = qt.edges
    assert edge.salted
    salted_over = float(edge.overload)
    plain_over = float(edge.plain_overload)
    assert plain_over > 2.0, plain_over
    assert salted_over < 1.3, salted_over

    run0 = executor.compile_plan(pq.plan(catalog, pods * n, num_pods=pods),
                                 tabs)
    raw0, qt0 = run0.collect(run0.dispatch())
    got0 = pq.finalize(raw0)
    np.testing.assert_allclose(float(got0), want, rtol=1e-3)
    (edge0,) = qt0.edges
    assert float(edge0.overload) == plain_over
    assert salted_over < float(edge0.overload)
    print("PASS salted_pod_shuffle")


def scenario_oocore_pod_stream():
    """Morsel-streamed Q17 ACROSS the process boundary: the chunked lineitem
    stream feeds the two-level (coarse cross-pod + fine in-pod) exchange one
    morsel at a time, result equal to the in-memory pod-mesh run."""
    from repro.relational import datagen
    from repro.relational.planner import tpch
    from repro.relational.planner.executor import execute_plan
    from repro.relational.planner.stream import compile_plan_streamed
    from repro.relational.source import MorselView, as_source

    mesh = _pod_mesh()
    pods, n = mesh.devices.shape
    tabs = datagen.gen_all(0.01)
    pq = tpch.q17()
    sources = {"lineitem": MorselView(tabs["lineitem"], morsel_rows=4096),
               "part": as_source(tabs["part"])}
    mat = {t: sources[t].materialize() for t in pq.tables}
    catalog = {t: sources[t].capacity for t in pq.tables}
    plan = pq.plan(catalog, pods * n, num_pods=pods)
    want = float(pq.finalize(execute_plan(plan, mat)))

    ctx = ExecutionContext(num_shards=pods * n, num_pods=pods)
    run = compile_plan_streamed(plan, sources, ctx)
    got = float(pq.finalize(run()))
    np.testing.assert_allclose(got, want, rtol=1e-3)
    assert run.stats["passes"] == 2, run.stats

    # spill is a single-level-mesh feature: over DCI it must refuse at
    # compile time, never drop rows at run time
    try:
        compile_plan_streamed(plan, sources, ctx.with_(spill=True))
    except NotImplementedError:
        pass
    else:
        raise AssertionError("spill on the pod mesh did not raise")
    print("PASS oocore_pod_stream")


def scenario_trace_merge():
    """One timeline for the whole cluster: each process traces its own Q17
    run and writes ``<dir>/q17-p<pid>.json``; after a cross-process
    barrier, process 0 merges them into a single Perfetto timeline whose
    events carry BOTH process tracks."""
    import json
    import shutil
    import tempfile

    from jax.experimental import multihost_utils

    from repro.obs.export import merge_trace_dir, write_trace_dir
    from repro.obs.trace import Tracer
    from repro.relational import datagen
    from repro.relational.planner import tpch

    # all processes of this cluster share a host; key the dir on the
    # coordinator address so concurrent clusters never collide
    tag = (INFO.coordinator or "solo").replace(":", "-").replace("/", "-")
    trace_dir = os.path.join(tempfile.gettempdir(), f"repro-trace-{tag}")
    if INFO.process_id == 0:
        shutil.rmtree(trace_dir, ignore_errors=True)
        os.makedirs(trace_dir, exist_ok=True)
    multihost_utils.sync_global_devices("trace-dir-ready")

    mesh = _pod_mesh()
    pods, n = mesh.devices.shape
    tabs = datagen.gen_all(0.01)
    pq = tpch.q17()
    tracer = Tracer()  # pid resolves to jax.process_index()
    assert tracer.pid == INFO.process_id
    tpch.run_query(
        pq, {t: tabs[t] for t in pq.tables},
        ExecutionContext(num_shards=pods * n, num_pods=pods, trace=tracer),
    )
    path = write_trace_dir(tracer, trace_dir, basename="q17")
    assert path.endswith(f"q17-p{INFO.process_id}.json")
    multihost_utils.sync_global_devices("traces-written")

    if INFO.process_id == 0:
        merged = merge_trace_dir(
            trace_dir, basename="q17",
            out=os.path.join(trace_dir, "merged.json"),
        )
        pids = {e["pid"] for e in merged["traceEvents"]}
        assert pids == set(range(INFO.num_processes)), pids
        # every process contributed its exchange spans and byte counters
        per_pid_names = {
            pid: {e["name"] for e in merged["traceEvents"]
                  if e["pid"] == pid and e["ph"] == "B"}
            for pid in pids
        }
        for pid, names in per_pid_names.items():
            assert any(nm.startswith("exchange:") for nm in names), (
                pid, names)
        assert merged["counters"]["exchange.measured_bytes"] > 0
        with open(os.path.join(trace_dir, "merged.json")) as f:
            json.load(f)  # Perfetto-loadable JSON on disk
    multihost_utils.sync_global_devices("merge-checked")
    if INFO.process_id == 0:
        shutil.rmtree(trace_dir, ignore_errors=True)
    print("PASS trace_merge")


SCENARIOS = {
    name.removeprefix("scenario_"): fn
    for name, fn in list(globals().items())
    if name.startswith("scenario_")
}

if __name__ == "__main__":
    which = sys.argv[1] if len(sys.argv) > 1 else "all"
    names = list(SCENARIOS) if which == "all" else [which]
    for nm in names:
        SCENARIOS[nm]()
