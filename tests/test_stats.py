"""Property tests for the estimation layer behind the adaptive planner.

Mirrors ``test_properties.py``: hypothesis is an optional test extra and the
module skips cleanly without it.  The properties pinned here are the ones
the planner's salting decision leans on:

* NDV estimates are exact when the sample covers the table and bounded
  otherwise (never below the observed distinct count, never above the
  row count);
* the SpaceSaving sketch NEVER misses a key whose true frequency exceeds
  ``n / capacity`` (the classic guarantee), and its guaranteed counts
  (``count - error``) never exceed true frequencies — so uniform data can
  never fabricate a heavy hitter;
* ``salt_keys`` round-trips through ``unsalt_keys`` for arbitrary uint64
  keys, and refuses the inputs the historical int64 version silently
  corrupted (negative keys, shifted values past 2**64);
* ``partition_overload`` estimates track a direct simulation of the
  runtime routing hash.
"""

import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="hypothesis is an optional test extra")
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import skew
from repro.relational import stats as S


# ---------------------------------------------------------------------------
# NDV estimation.
# ---------------------------------------------------------------------------

@given(st.integers(1, 500), st.integers(0, 2**31 - 1))
@settings(max_examples=40, deadline=None)
def test_ndv_exact_on_full_sample(n, seed):
    rng = np.random.default_rng(seed)
    vals = rng.integers(0, max(n // 2, 1), n)
    # sample == table: the unseen-species term must vanish
    assert S.estimate_ndv(vals, n) == len(np.unique(vals))


@given(
    st.integers(2_000, 20_000),  # table rows
    st.integers(10, 2_000),      # key domain
    st.sampled_from([None, 1.1, 1.5]),  # uniform or Zipf exponent
    st.integers(0, 2**31 - 1),
)
@settings(max_examples=25, deadline=None)
def test_ndv_bounded_on_subsample(rows, domain, z, seed):
    rng = np.random.default_rng(seed)
    if z is None:
        vals = rng.integers(0, domain, rows)
    else:
        pmf = np.arange(1, domain + 1, dtype=np.float64) ** -z
        vals = rng.choice(domain, size=rows, p=pmf / pmf.sum())
    sample = rng.choice(vals, size=1024, replace=False)
    est = S.estimate_ndv(sample, rows)
    true_ndv = len(np.unique(vals))
    seen = len(np.unique(sample))
    assert seen <= est <= rows      # hard bounds, always
    # GEE's ratio-error guarantee: within sqrt(rows / sample) of truth
    # (small slack for the randomness of one concrete sample)
    bound = 1.5 * np.sqrt(rows / sample.size)
    assert est <= bound * true_ndv
    assert est >= true_ndv / bound


# ---------------------------------------------------------------------------
# Selectivity estimation runs the SAME Expr.eval the executor runs.
# ---------------------------------------------------------------------------

@given(st.integers(1, 300), st.integers(0, 100), st.integers(0, 2**31 - 1))
@settings(max_examples=30, deadline=None)
def test_selectivity_exact_on_full_sample(n, cut, seed):
    from repro.relational.planner import logical as L

    rng = np.random.default_rng(seed)
    sample = {"x": rng.integers(0, 100, n).astype(np.int32)}
    got = L.predicate_selectivity(L.col("x") < L.lit(cut), sample)
    assert got == pytest.approx(float((sample["x"] < cut).mean()))


# ---------------------------------------------------------------------------
# SpaceSaving: the no-miss guarantee and the no-phantom guarantee.
# ---------------------------------------------------------------------------

@given(
    st.integers(4, 16),          # sketch capacity
    st.integers(100, 3_000),     # stream length
    st.floats(1.05, 2.0),        # Zipf exponent
    st.integers(0, 2**31 - 1),
)
@settings(max_examples=25, deadline=None)
def test_sketch_never_misses_heavy_keys(cap, n, z, seed):
    rng = np.random.default_rng(seed)
    domain = 500
    pmf = np.arange(1, domain + 1, dtype=np.float64) ** -z
    stream = rng.choice(domain, size=n, p=pmf / pmf.sum())
    sk = S.SpaceSaving(cap)
    sk.update_many(stream.tolist())
    in_sketch = {k for k, _, _ in sk.entries()}
    counts = np.bincount(stream, minlength=domain)
    for key in np.flatnonzero(counts > n / cap):
        assert int(key) in in_sketch, (
            f"key {key} (freq {counts[key]}/{n} > n/capacity) missing"
        )


@given(st.integers(2, 16), st.integers(50, 2_000), st.integers(0, 2**31 - 1))
@settings(max_examples=30, deadline=None)
def test_sketch_guaranteed_counts_never_exceed_truth(cap, n, seed):
    rng = np.random.default_rng(seed)
    stream = rng.integers(0, 200, n)  # uniform: the phantom-heavy-hitter case
    sk = S.SpaceSaving(cap)
    sk.update_many(stream.tolist())
    counts = np.bincount(stream, minlength=200)
    for key, c, err in sk.entries():
        assert c - err <= counts[key] <= c  # guaranteed <= true <= estimate


def test_uniform_data_yields_no_heavy_hitters():
    """The planner-facing regression: count inheritance alone must not
    promote a uniform key to heavy (it did, before error tracking)."""
    rng = np.random.default_rng(7)
    cs = S._profile_column("k", rng.integers(0, 10_000, 2048), 100_000)
    assert cs.heavy_hitters == ()


# ---------------------------------------------------------------------------
# salt_keys round-trip and the uint64/int64 overflow bug class.
# ---------------------------------------------------------------------------

@given(
    st.lists(st.integers(0, 2**64 - 1), min_size=1, max_size=64),
    st.integers(1, 512),
    st.integers(0, 2**31 - 1),
)
@settings(max_examples=50, deadline=None)
def test_salt_keys_round_trip_or_reject(keys, num_salts, seed):
    keys = np.asarray(keys, dtype=np.uint64)
    heavy = keys[:: max(len(keys) // 3, 1)]
    if num_salts > 1 and int(keys.max()) >= 2**64 // num_salts:
        with pytest.raises(ValueError, match="overflow"):
            skew.salt_keys(keys, heavy, num_salts, seed=seed)
        return
    salted = skew.salt_keys(keys, heavy, num_salts, seed=seed)
    assert salted.dtype == np.uint64
    np.testing.assert_array_equal(skew.unsalt_keys(salted, num_salts), keys)
    # non-heavy keys shift deterministically; heavy sub-keys stay in-range
    non_heavy = ~np.isin(keys, heavy)
    np.testing.assert_array_equal(
        salted[non_heavy], keys[non_heavy] * np.uint64(num_salts)
    )
    assert (salted - keys * np.uint64(num_salts) < num_salts).all()


def test_salt_keys_rejects_negative_keys():
    """int64 -1 casts to 2**64 - 1: salting it silently aliased the largest
    uint64 key.  Now it raises."""
    with pytest.raises(ValueError, match="negative"):
        skew.salt_keys(np.asarray([3, -1], np.int64), [3], 4)


def test_salt_keys_rejects_uint64_shift_overflow():
    with pytest.raises(ValueError, match="overflow"):
        skew.salt_keys(np.asarray([2**63], np.uint64), [], 4)


def test_partition_overload_handles_huge_uint64_keys():
    """Regression for the np.bincount-refuses-uint64 path: heavy keys near
    2**32 (post-hash values are 32-bit) must not crash or go negative."""
    heavy = [(2**32 - 1, 0.5), (2**31 + 17, 0.3)]
    over = S.partition_overload(heavy, 8)
    assert 1.0 <= over <= 8.0
    salted = S.partition_overload(heavy, 8, num_salts=512,
                                  salted=[k for k, _ in heavy])
    assert salted < over


# ---------------------------------------------------------------------------
# partition_overload tracks a direct routing simulation.
# ---------------------------------------------------------------------------

@given(st.integers(2, 16), st.floats(1.1, 1.6), st.integers(0, 2**31 - 1))
@settings(max_examples=15, deadline=None)
def test_overload_estimate_tracks_simulation(shards, z, seed):
    rng = np.random.default_rng(seed)
    domain, n = 1000, 30_000
    pmf = np.arange(1, domain + 1, dtype=np.float64) ** -z
    keys = rng.choice(domain, size=n, p=pmf / pmf.sum())
    # the true overload, routed exactly like the executor routes
    dest = (S.fib_hash32(keys) % np.uint64(shards)).astype(np.int64)
    true_over = np.bincount(dest, minlength=shards).max() * shards / n
    # the estimate, from an exact heavy-hitter profile
    counts = np.bincount(keys, minlength=domain)
    heavy = [(int(k), counts[k] / n) for k in np.argsort(-counts)[:32]
             if counts[k] >= 4]
    est = S.partition_overload(heavy, shards)
    assert est == pytest.approx(true_over, rel=0.35)


def test_fib_hash32_matches_runtime_hash():
    """The planner's placement model must use the EXACT routing hash."""
    import jax.numpy as jnp

    from repro.kernels import ref as KR

    keys = np.asarray([0, 1, 17, 2**31 - 1, 12345], np.int64)
    want = np.asarray(KR.fibonacci_hash_ref(jnp.asarray(keys, jnp.int32)))
    np.testing.assert_array_equal(S.fib_hash32(keys).astype(np.uint32), want)
