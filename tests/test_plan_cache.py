"""Property tests for the plan-cache key layer (hypothesis optional extra).

The cache key's contract, pinned as properties:

* the canonical render / digest is a pure function of plan STRUCTURE —
  identical across DAG construction orders (shared subtree vs duplicated
  equal subtree) and across process restarts (no id()/hash-seed leakage);
* distinct logical plans, catalogs, mesh shapes, and stats buckets never
  collide: parameter tuples differ iff renders differ iff digests differ;
* the stats bucket is deterministic, drops sampling-noise heavy hitters,
  and SHIFTS when real skew appears — which invalidates the cache entry
  (the second lookup replans, observed via the ``plan_physical.calls``
  counter hook);
* persisted entries verify their key material: a digest file whose
  material mismatches reads as a miss, never a wrong plan.
"""

import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="hypothesis is an optional test extra")
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.relational import stats as S
from repro.relational.planner import logical as L
from repro.relational.planner.physical import PlannerConfig, plan_physical
from repro.relational.planner.plan_cache import (
    PlanCache,
    canonical_render,
    plan_key,
    stats_bucket,
)
from repro.relational.planner.tpch import ALL_QUERIES

CATALOG = {"t": 4096, "u": 512}


# ---------------------------------------------------------------------------
# A tiny plan grammar: every draw returns (params, node) where ``params``
# fully determines the structure — so render collisions are checkable.
# ---------------------------------------------------------------------------

@st.composite
def plans(draw):
    cols = draw(st.sampled_from([("a", "b"), ("a", "c"), ("a", "b", "c")]))
    node = L.Scan("t", cols)
    thresh = draw(st.none() | st.integers(0, 100))
    if thresh is not None:
        node = L.Filter(node, L.col("a") < L.lit(thresh))
    join = draw(st.booleans())
    if join:
        payload = draw(st.sampled_from([("v",), ()]))
        node = L.HashJoin(
            build=L.Scan("u", ("k", "v")), probe=node,
            build_key="k", probe_key="a", payload=payload,
        )
    else:
        payload = None
    terminal = draw(st.sampled_from(["agg", "topk", "none"]))
    if terminal == "agg":
        node = L.Aggregate(node, (("s", L.col("a"), "sum"),))
    elif terminal == "topk":
        k = draw(st.integers(1, 8))
        node = L.TopK(node, key="a", k=k, payload=("a",))
    else:
        k = None
    params = (cols, thresh, join, payload, terminal,
              k if terminal == "topk" else None)
    return params, node


@given(plans(), plans())
@settings(max_examples=80, deadline=None)
def test_render_is_injective_over_the_grammar(p1, p2):
    """Different structures never share a render; equal structures always
    do — the collision half is what makes the digest trustworthy."""
    (params1, n1), (params2, n2) = p1, p2
    assert (params1 == params2) == (canonical_render(n1) == canonical_render(n2))
    if params1 != params2:
        k1 = plan_key(n1, CATALOG, 8)
        k2 = plan_key(n2, CATALOG, 8)
        assert k1.digest != k2.digest


@given(plans())
@settings(max_examples=40, deadline=None)
def test_key_stable_across_reconstruction(p):
    """Rebuilding the same logical DAG from scratch (fresh objects, fresh
    order) yields the same render and digest — identity never leaks in."""
    params, node = p
    rerendered = canonical_render(node)
    assert canonical_render(node) == rerendered  # idempotent
    k1 = plan_key(node, CATALOG, 8)
    k2 = plan_key(node, dict(reversed(list(CATALOG.items()))), 8)
    assert k1.digest == k2.digest  # catalog dict order is not identity


def test_shared_vs_duplicated_subtree_render_identically():
    """Construction order / sharing is an executor concern, not identity:
    a self-join via ONE shared Scan object renders the same as one built
    from two equal Scan objects."""
    shared = L.Scan("t", ("a", "b"))
    j_shared = L.HashJoin(
        build=shared, probe=shared, build_key="a", probe_key="a",
        payload=(),
    )
    j_dup = L.HashJoin(
        build=L.Scan("t", ("a", "b")), probe=L.Scan("t", ("a", "b")),
        build_key="a", probe_key="a", payload=(),
    )
    assert canonical_render(j_shared) == canonical_render(j_dup)
    assert (
        plan_key(j_shared, CATALOG, 8).digest
        == plan_key(j_dup, CATALOG, 8).digest
    )


@given(
    st.sampled_from([(1, 1), (4, 1), (8, 1), (8, 2), (16, 4)]),
    st.sampled_from([(1, 1), (4, 1), (8, 1), (8, 2), (16, 4)]),
)
@settings(max_examples=25, deadline=None)
def test_distinct_mesh_shapes_never_collide(m1, m2):
    node = ALL_QUERIES["q6"]().logical
    cat = {"lineitem": 8192}
    k1 = plan_key(node, cat, m1[0], num_pods=m1[1])
    k2 = plan_key(node, cat, m2[0], num_pods=m2[1])
    assert (m1 == m2) == (k1.digest == k2.digest)


@given(st.integers(1, 10**7), st.integers(1, 10**7))
@settings(max_examples=40, deadline=None)
def test_distinct_catalogs_never_collide(cap1, cap2):
    node = ALL_QUERIES["q6"]().logical
    k1 = plan_key(node, {"lineitem": cap1}, 8)
    k2 = plan_key(node, {"lineitem": cap2}, 8)
    assert (cap1 == cap2) == (k1.digest == k2.digest)


# ---------------------------------------------------------------------------
# Stats bucketing.
# ---------------------------------------------------------------------------

def _profile(rows: int, heavy: tuple = (), ndv: int = 100) -> dict:
    cs = S.ColumnStats(
        name="a", ndv=ndv, heavy_hitters=heavy,
        max_share=heavy[0][1] if heavy else 0.001,
    )
    prof = S.TableProfile(
        table="t", rows=rows, sample_rows=min(rows, 1024),
        columns={"a": cs}, sample={"a": np.zeros(4, np.int64)},
    )
    return {"t": prof}


@given(st.integers(1, 10**6), st.integers(1, 10**6))
@settings(max_examples=40, deadline=None)
def test_stats_bucket_rows_quantize_to_decades(r1, r2):
    b1, b2 = stats_bucket(_profile(r1)), stats_bucket(_profile(r2))
    same_bucket = r1.bit_length() == r2.bit_length()
    assert (b1 == b2) == same_bucket


def test_stats_bucket_static_vs_profiled_and_noise_floor():
    assert stats_bucket(None) == "static"
    assert stats_bucket(None) != stats_bucket(_profile(1000))
    # shares under the noise floor are sampling artifacts, not skew —
    # they must not perturb the bucket...
    assert stats_bucket(_profile(1000, heavy=((7, 0.001),))) == \
        stats_bucket(_profile(1000))
    # ...but a real heavy hitter must
    assert stats_bucket(_profile(1000, heavy=((7, 0.3),))) != \
        stats_bucket(_profile(1000))
    # and only its magnitude class matters, not its sampled decimals
    assert stats_bucket(_profile(1000, heavy=((7, 0.30),))) == \
        stats_bucket(_profile(1000, heavy=((7, 0.33),)))


def test_stats_bucket_shift_invalidates_entry():
    """The satellite contract: when the stats bucket shifts, the second
    lookup REPLANS instead of serving the stale plan."""
    node = ALL_QUERIES["q6"]().logical
    cat = {"lineitem": 8192}
    cache = PlanCache()
    cfg = PlannerConfig(num_units=8, hybrid=True)

    def planner():
        return plan_physical(node, cat, 8, cfg=cfg, name="q6")

    uniform = {"lineitem": _profile(8192)["t"]}
    skewed = {"lineitem": _profile(8192, heavy=((7, 0.4),))["t"]}
    k_uni = plan_key(node, cat, 8, cfg=cfg, stats=uniform)
    k_skew = plan_key(node, cat, 8, cfg=cfg, stats=skewed)
    assert k_uni.digest != k_skew.digest

    before = plan_physical.calls
    _, hit = cache.get_plan(k_uni, planner)
    assert not hit and plan_physical.calls == before + 1
    _, hit = cache.get_plan(k_uni, planner)
    assert hit and plan_physical.calls == before + 1  # warm: no replan
    _, hit = cache.get_plan(k_skew, planner)  # bucket shifted -> replan
    assert not hit and plan_physical.calls == before + 2


# ---------------------------------------------------------------------------
# Persistence safety.
# ---------------------------------------------------------------------------

def test_material_mismatch_reads_as_miss(tmp_path):
    """A persisted entry is trusted only if its stored key material matches
    byte-for-byte — a forged/colliding digest can never return a wrong
    plan, and a corrupt file is a miss, not an error."""
    import dataclasses

    node = ALL_QUERIES["q6"]().logical
    cat = {"lineitem": 8192}
    key = plan_key(node, cat, 8)
    cache = PlanCache(cache_dir=str(tmp_path))
    plan, _ = cache.get_plan(key, lambda: plan_physical(node, cat, 8, name="q6"))

    fresh = PlanCache(cache_dir=str(tmp_path))
    forged = dataclasses.replace(key, material=key.material + "?")
    assert fresh.lookup(forged) is None
    (entry,) = tmp_path.glob("plan-*.pkl")
    entry.write_bytes(b"not a pickle")
    assert PlanCache(cache_dir=str(tmp_path)).lookup(key) is None
