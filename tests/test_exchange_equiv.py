"""Cross-impl equivalence of the decoupled exchange, on 8 fake devices.

Asserts that ``all_to_all`` / ``hash_shuffle`` / the streaming consume
deliver identical results across every transport (``xla`` / ``round_robin``
/ ``one_factorization``), pack implementation (``xla`` one-hot vs ``pallas``
fused kernel) and pipeline chunking — including a heavily skewed key
distribution — and that the scheduled transport + Pallas pack reproduces the
TPC-H join queries bit-exactly.

Like test_multidevice.py, each scenario runs in a subprocess so the XLA
fake-device flag is set before jax initializes.
"""

import os
import subprocess
import sys

import pytest

DRIVER = os.path.join(os.path.dirname(__file__), "_multidev_driver.py")

SCENARIOS = [
    "hash_shuffle_equiv",
    "consume_equiv",
    "mux_schedule_fallback",
    "autotune_mux",
    "tpch_pack_equiv",
]


@pytest.mark.parametrize("scenario", SCENARIOS)
def test_exchange_equiv(scenario):
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run(
        [sys.executable, DRIVER, scenario],
        capture_output=True, text=True, timeout=420, env=env,
    )
    assert proc.returncode == 0, proc.stdout + "\n" + proc.stderr
    assert f"PASS {scenario}" in proc.stdout
