"""Launcher + topology-derived mesh construction (no multi-device mesh).

The live 2-process behaviour runs in tests/test_multiprocess.py; here we
cover the spawner mechanics with jax-free workers (fast) and the actionable
failure modes of the pod-shape derivation.
"""


import pytest

from repro.launch.cluster import (
    ENV_LOCAL_DEVICES,
    ENV_NUM_PROCESSES,
    ENV_PROCESS_ID,
    init_cluster,
    run_local_cluster,
)
from repro.launch.mesh import (
    _squarest_factors,
    make_pod_mesh,
    make_production_mesh,
)


def test_squarest_factors():
    assert _squarest_factors(256) == (16, 16)
    assert _squarest_factors(8) == (2, 4)
    assert _squarest_factors(7) == (1, 7)
    assert _squarest_factors(12) == (3, 4)


def test_run_local_cluster_sets_worker_env():
    outputs = run_local_cluster(
        ["-c",
         "import os;print(os.environ['%s'], os.environ['%s'], "
         "os.environ['%s'])" % (ENV_PROCESS_ID, ENV_NUM_PROCESSES,
                                ENV_LOCAL_DEVICES)],
        num_processes=2, local_devices=3, timeout_s=60, echo=False,
    )
    assert [o.split()[0] for o in outputs] == ["0", "1"]
    assert all(o.split()[1:] == ["2", "3"] for o in outputs)


def test_run_local_cluster_surfaces_worker_failure():
    with pytest.raises(RuntimeError, match="boom"):
        run_local_cluster(
            ["-c", "raise SystemExit('boom')"],
            num_processes=2, timeout_s=60, echo=False,
        )


def test_run_local_cluster_timeout_kills_workers():
    with pytest.raises(RuntimeError, match="timed out"):
        run_local_cluster(
            ["-c", "import time; time.sleep(60)"],
            num_processes=1, timeout_s=2, echo=False,
        )


def test_init_cluster_is_noop_outside_a_launch(monkeypatch):
    for var in (ENV_PROCESS_ID, ENV_NUM_PROCESSES, ENV_LOCAL_DEVICES):
        monkeypatch.delenv(var, raising=False)
    info = init_cluster()
    assert info.num_processes == 1 and info.process_id == 0


def test_production_mesh_single_process_needs_pod_override():
    # pytest runs single-process: multi_pod without an override must point
    # at the launcher, not die in a reshape five layers down.
    with pytest.raises(ValueError, match="repro.launch.cluster"):
        make_production_mesh(multi_pod=True)


def test_production_mesh_rejects_non_factoring_pods():
    # 1 CPU device visible in-process: 1 % 2 != 0
    with pytest.raises(ValueError, match="do not split"):
        make_production_mesh(multi_pod=True, num_pods=2)


def test_pod_mesh_rejects_non_factoring_pods():
    with pytest.raises(ValueError, match="pods"):
        make_pod_mesh(num_pods=3)


def test_cluster_cli_runs_a_trivial_worker():
    from repro.launch import cluster

    rc = cluster.main(
        ["--processes", "2", "--timeout", "60", "--",
         "-c", "print('worker alive')"]
    )
    assert rc == 0


def test_cluster_cli_missing_worker():
    from repro.launch import cluster

    with pytest.raises(SystemExit):
        cluster.main(["--processes", "2"])
