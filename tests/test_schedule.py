"""Property tests for the paper's conflict-free phase schedules (Fig 10a).

``hypothesis`` is an optional test dependency; without it this module skips
cleanly at collection (the non-property schedule checks live in
tests/test_multiplexer.py, which has no optional deps).
"""

import pytest

pytest.importorskip("hypothesis", reason="hypothesis is an optional test extra")
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import schedule as S


@given(st.integers(2, 64))
@settings(max_examples=40, deadline=None)
def test_shift_schedule_is_conflict_free_and_complete(n):
    S.verify_schedule(S.shift_schedule(n))


@given(st.integers(1, 32).map(lambda k: 2 * k))
@settings(max_examples=30, deadline=None)
def test_one_factorization_is_conflict_free_and_complete(n):
    S.verify_schedule(S.one_factorization(n))


@given(st.integers(2, 32))
@settings(max_examples=30, deadline=None)
def test_num_phases_is_n_minus_1(n):
    assert S.shift_schedule(n).num_phases == n - 1


@given(st.integers(2, 24), st.integers(0, 100))
@settings(max_examples=40, deadline=None)
def test_sources_and_targets_are_inverse(n, dev):
    sched = S.shift_schedule(n)
    d = dev % n
    # if d sends to t in phase k, then t receives from d in phase k
    for k, t in enumerate(sched.targets_for(d)):
        assert sched.sources_for(t)[k] == d


def test_verify_rejects_self_send():
    bad = S.Schedule(n=2, phases=(((0, 0), (1, 1)),))
    with pytest.raises(AssertionError):
        S.verify_schedule(bad)


def test_verify_rejects_duplicate_pair():
    bad = S.Schedule(n=2, phases=(((0, 1), (1, 0)), ((0, 1), (1, 0))))
    with pytest.raises(AssertionError):
        S.verify_schedule(bad)


@given(st.integers(2, 16), st.integers(0, 40))
@settings(max_examples=30, deadline=None)
def test_ring_hops_short_way(n, k):
    h = S.ring_hops(n, k)
    assert 0 <= h <= n // 2


def test_scheduled_beats_unscheduled_analytically():
    """Fig 10(b): scheduling wins whenever contention degrades links."""
    t_sched = S.schedule_link_time(8, 1e6, 1e9, scheduled=True)
    t_unsched = S.schedule_link_time(8, 1e6, 1e9, scheduled=False)
    assert t_unsched > t_sched


def test_contention_simulator_matches_paper_order_of_magnitude():
    """Paper: +40 % all-to-all throughput at 8 servers."""
    from repro.core.topology import scheduled_vs_unscheduled_speedup

    speedup = scheduled_vs_unscheduled_speedup(8)
    assert 1.15 <= speedup <= 1.8, speedup
