"""Execute every ```python code block in the user-facing docs.

    PYTHONPATH=src python tools/check_docs.py [files...]

Defaults to README.md and docs/ARCHITECTURE.md.  Each block runs in its own
subprocess (so a block's `os.environ` setup — e.g. XLA fake devices — takes
effect before jax initializes, and blocks cannot leak state into each
other).  Any non-zero exit fails the run — this is what keeps the snippets
executable instead of decorative.  Fenced blocks tagged anything other than
``python`` (``bash``, ``text``, ...) are skipped.

Run by the CI docs job and by tests/test_docs.py.
"""

from __future__ import annotations

import os
import re
import subprocess
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DEFAULT_FILES = (
    "README.md",
    os.path.join("docs", "ARCHITECTURE.md"),
    os.path.join("docs", "MULTIHOST.md"),
    os.path.join("docs", "SERVING.md"),
    os.path.join("docs", "DATA.md"),
    os.path.join("docs", "OBSERVABILITY.md"),
)
FENCE = re.compile(r"^```(\w*)\s*$")


def python_blocks(path: str) -> list[tuple[int, str]]:
    """(start_line, source) of every ```python fenced block in ``path``."""
    blocks, current, lang, start = [], None, None, 0
    with open(path, encoding="utf-8") as f:
        for lineno, line in enumerate(f, 1):
            m = FENCE.match(line.strip())
            if m and current is None:
                lang, current, start = m.group(1), [], lineno + 1
            elif line.strip() == "```" and current is not None:
                if lang == "python":
                    blocks.append((start, "".join(current)))
                current = None
            elif current is not None:
                current.append(line)
    assert current is None, f"{path}: unterminated code fence"
    return blocks


def run_block(path: str, lineno: int, source: str, timeout: int = 600) -> bool:
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else ""
    )
    proc = subprocess.run(
        [sys.executable, "-c", source],
        cwd=REPO, env=env, capture_output=True, text=True, timeout=timeout,
    )
    label = f"{os.path.relpath(path, REPO)}:{lineno}"
    if proc.returncode:
        print(f"FAIL {label}\n--- stdout ---\n{proc.stdout}"
              f"\n--- stderr ---\n{proc.stderr}")
        return False
    print(f"ok   {label}")
    return True


def main(argv: list[str]) -> int:
    files = argv or [os.path.join(REPO, f) for f in DEFAULT_FILES]
    failures = total = 0
    for path in files:
        for lineno, source in python_blocks(path):
            total += 1
            if not run_block(path, lineno, source):
                failures += 1
    print(f"{total - failures}/{total} doc blocks passed")
    return 1 if failures or not total else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
